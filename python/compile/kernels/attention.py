"""Pallas causal attention kernel (L1 hot-spot).

Flash-attention-style tiling rethought for TPU (DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks staging K/V through
shared memory, the grid is (batch*heads, q-blocks) and ``BlockSpec``s
stage VMEM-resident tiles — a [BLK_Q, D] query tile and [S, D] key/value
tiles per program — while an online-softmax ``fori_loop`` walks key blocks
so the [S, S] score matrix is never materialised. MXU-friendly shapes:
BLK_Q and BLK_K multiples of the 128-lane register tiling.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (numerically identical;
real-TPU performance is estimated in DESIGN.md §Perf instead of measured).

The kernel is wrapped in ``jax.custom_vjp``: forward runs the Pallas
kernel, backward uses the exact pure-jnp attention gradient (the paper's
contribution is the communication scheduler, not a bwd kernel; XLA fuses
the reference backward well). Gradcheck lives in test_kernels.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# 128-wide tiles: MXU/VPU-aligned and few interpret-mode grid steps.
BLK_Q = 128
BLK_K = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, blk_q, blk_k, seq, causal):
    """One (batch*head, q-block) program: online softmax over key blocks."""
    qi = pl.program_id(1)
    q = q_ref[0, :, :]  # [blk_q, d]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))

    q_pos = qi * blk_q + jnp.arange(blk_q)

    def body(t, carry):
        acc, row_max, row_sum = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k_ref[0, :, :], t * blk_k, blk_k, axis=0)
        v_blk = jax.lax.dynamic_slice_in_dim(v_ref[0, :, :], t * blk_k, blk_k, axis=0)
        s = (q @ k_blk.T) * scale  # [blk_q, blk_k]
        if causal:
            k_pos = t * blk_k + jnp.arange(blk_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, -jnp.inf)
        new_max = jnp.maximum(row_max, s.max(axis=-1))
        # Guard fully-masked rows (new_max = -inf): exp(-inf - -inf) -> nan.
        safe_max = jnp.where(jnp.isfinite(new_max), new_max, 0.0)
        correction = jnp.exp(jnp.where(jnp.isfinite(row_max), row_max - safe_max, -jnp.inf))
        p = jnp.exp(s - safe_max[:, None])
        acc = acc * correction[:, None] + p @ v_blk
        row_sum = row_sum * correction + p.sum(axis=-1)
        return acc, new_max, row_sum

    n_blocks = seq // blk_k
    acc0 = jnp.zeros_like(q)
    max0 = jnp.full((blk_q,), -jnp.inf, dtype=q.dtype)
    sum0 = jnp.zeros((blk_q,), dtype=q.dtype)
    acc, _, row_sum = jax.lax.fori_loop(0, n_blocks, body, (acc0, max0, sum0))
    o_ref[0, :, :] = acc / jnp.maximum(row_sum, 1e-30)[:, None]


def _attention_fwd_pallas(q, k, v, *, causal):
    """[B, H, S, D] attention via the Pallas kernel."""
    b, h, s, d = q.shape
    blk_q = min(BLK_Q, s)
    blk_k = min(BLK_K, s)
    assert s % blk_q == 0 and s % blk_k == 0, f"seq {s} not divisible by blocks"
    bh = b * h
    qr = q.reshape(bh, s, d)
    kr = k.reshape(bh, s, d)
    vr = v.reshape(bh, s, d)
    kernel = functools.partial(
        _attn_kernel, blk_q=blk_q, blk_k=blk_k, seq=s, causal=causal
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, s // blk_q),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=True,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal=True):
    """Causal attention: Pallas forward, reference-exact backward."""
    return _attention_fwd_pallas(q, k, v, causal=causal)


def _attention_fwd(q, k, v, causal):
    return _attention_fwd_pallas(q, k, v, causal=causal), (q, k, v)


def _attention_bwd(causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)
