"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact reference here; pytest
(`python/tests/test_kernels.py`) asserts allclose between the two across a
hypothesis-driven sweep of shapes and dtypes. The references are also the
building blocks of the model's backward pass where a hand-written Pallas
VJP would add no fidelity to the paper's contribution (the scheduler).
"""

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True):
    """Scaled dot-product attention over [B, H, S, D] tensors."""
    b, h, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None, None, :, :], scores, -jnp.inf)
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", weights, v)


def bucket_reduce_ref(grads):
    """Mean-reduce worker gradient slabs: [W, N] -> [N].

    This is the arithmetic half of a ring allreduce — the reduction the
    paper's NCCL/gloo transports perform on each bucket.
    """
    return jnp.mean(grads, axis=0)


def sgd_update_ref(p, g, m, lr, scale, beta):
    """Fused momentum-SGD bucket update.

    m' = beta * m + g * scale        (scale = 1/k for k-iteration merges)
    p' = p - lr * m'
    """
    m_new = beta * m + g * scale
    p_new = p - lr * m_new
    return p_new, m_new
