"""Pallas gradient-bucket reduction kernel (the allreduce arithmetic).

Reduces W workers' gradient slabs ``[W, N] -> [N]`` (mean). This is the
compute half of the ring allreduce each bucket undergoes; the Rust
coordinator calls the AOT-compiled ``grad_reduce`` executable on its hot
path instead of looping in Rust.

TPU adaptation: the kernel is bandwidth-bound, so there is no MXU use —
the grid tiles the N axis into ``BLK``-sized chunks (multiples of the
128-lane VPU tiling) and each program holds a [W, BLK] tile in VMEM,
reducing over the (small) worker axis. Ragged tails are handled by the
wrapper with zero-padding (mean is computed with the true W).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Interpret-mode lowering pays ~10 ms per grid step on CPU (each step
# becomes a dynamic-slice + body + dynamic-update-slice in a while
# loop), so blocks are sized to make most buckets single-step. On a
# real TPU this would be VMEM-bounded (~2 MiB tiles) instead — see
# DESIGN.md section Perf.
BLK = 1 << 20


def _reduce_kernel(g_ref, o_ref, *, inv_w):
    o_ref[...] = jnp.sum(g_ref[...], axis=0) * inv_w


def bucket_reduce(grads):
    """Mean over the leading worker axis: [W, N] -> [N] via Pallas."""
    w, n = grads.shape
    blk = min(BLK, n)
    padded = ((n + blk - 1) // blk) * blk
    if padded != n:
        grads = jnp.pad(grads, ((0, 0), (0, padded - n)))
    kernel = functools.partial(_reduce_kernel, inv_w=1.0 / w)
    out = pl.pallas_call(
        kernel,
        grid=(padded // blk,),
        in_specs=[pl.BlockSpec((w, blk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), grads.dtype),
        interpret=True,
    )(grads)
    return out[:n]
