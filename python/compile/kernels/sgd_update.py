"""Pallas fused momentum-SGD bucket update kernel.

PyTorch DDP launches separate kernels for the momentum update and the
parameter step; this fuses both into one pass per bucket:

    m' = beta * m + g * scale      (scale = 1/k for DeFT's k-way merges)
    p' = p - lr * m'

Scalars (lr, scale, beta) travel as [1]-shaped runtime inputs so the Rust
coordinator can adjust them per update without recompiling; their
BlockSpec maps every grid step to the same single-element block.

Grid tiles the flat bucket into VPU-lane-aligned chunks held in VMEM —
one read and one write per operand, the bandwidth roofline for this op.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Single-step blocks for CPU interpret mode (see bucket_reduce.py).
BLK = 1 << 20


def _update_kernel(p_ref, g_ref, m_ref, lr_ref, scale_ref, beta_ref, po_ref, mo_ref):
    lr = lr_ref[0]
    scale = scale_ref[0]
    beta = beta_ref[0]
    m_new = beta * m_ref[...] + g_ref[...] * scale
    po_ref[...] = p_ref[...] - lr * m_new
    mo_ref[...] = m_new


def sgd_update(p, g, m, lr, scale, beta):
    """Fused update over a flat [N] bucket; lr/scale/beta are [1] arrays.

    Returns (new_params, new_momentum).
    """
    (n,) = p.shape
    blk = min(BLK, n)
    padded = ((n + blk - 1) // blk) * blk
    if padded != n:
        pad = ((0, padded - n),)
        p = jnp.pad(p, pad)
        g = jnp.pad(g, pad)
        m = jnp.pad(m, pad)
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    chunk_spec = pl.BlockSpec((blk,), lambda i: (i,))
    p_new, m_new = pl.pallas_call(
        _update_kernel,
        grid=(padded // blk,),
        in_specs=[chunk_spec, chunk_spec, chunk_spec, scalar_spec, scalar_spec, scalar_spec],
        out_specs=[chunk_spec, chunk_spec],
        out_shape=[
            jax.ShapeDtypeStruct((padded,), p.dtype),
            jax.ShapeDtypeStruct((padded,), m.dtype),
        ],
        interpret=True,
    )(p, g, m, lr, scale, beta)
    return p_new[:n], m_new[:n]
