"""L2 — the bucketed GPT-style transformer (build-time JAX).

The model's parameters live as **flat f32 bucket vectors** — the exact
abstraction the paper's scheduler works with. The Rust coordinator only
ever sees ``b0..b{K-1}``; this module owns the mapping from buckets to
weight tensors (``unflatten``) and builds the three AOT entry points:

* ``train_step(b0..bK-1, tokens) -> (loss, g0..gK-1)`` — forward + backward
  of one batch; attention runs the L1 Pallas kernel.
* ``apply_update(b*, g*, m*, lr, scale) -> (b'*, m'*)`` — fused
  momentum-SGD per bucket via the L1 Pallas update kernel (``scale``
  implements DeFT's merged/accumulated updates).
* ``grad_reduce(stacked g) -> mean g`` — per-bucket mean over workers via
  the L1 Pallas reduction kernel (the allreduce arithmetic).

Tokens come in as ``[batch, seq+1]``: positions 0..seq-1 are inputs,
1..seq are next-token targets.
"""

import dataclasses
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention, bucket_reduce, sgd_update


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    seq: int = 128
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    batch: int = 8
    n_buckets: int = 4

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


def param_shapes(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Parameter tensors in forward order (the bucketing order)."""
    shapes: List[Tuple[str, Tuple[int, ...]]] = [
        ("wte", (cfg.vocab, cfg.d_model)),
        ("wpe", (cfg.seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        shapes += [
            (f"h{i}.ln1_g", (cfg.d_model,)),
            (f"h{i}.ln1_b", (cfg.d_model,)),
            (f"h{i}.qkv_w", (cfg.d_model, 3 * cfg.d_model)),
            (f"h{i}.qkv_b", (3 * cfg.d_model,)),
            (f"h{i}.proj_w", (cfg.d_model, cfg.d_model)),
            (f"h{i}.proj_b", (cfg.d_model,)),
            (f"h{i}.ln2_g", (cfg.d_model,)),
            (f"h{i}.ln2_b", (cfg.d_model,)),
            (f"h{i}.fc_w", (cfg.d_model, cfg.d_ff)),
            (f"h{i}.fc_b", (cfg.d_ff,)),
            (f"h{i}.out_w", (cfg.d_ff, cfg.d_model)),
            (f"h{i}.out_b", (cfg.d_model,)),
        ]
    shapes += [
        ("lnf_g", (cfg.d_model,)),
        ("lnf_b", (cfg.d_model,)),
        ("head", (cfg.d_model, cfg.vocab)),
    ]
    return shapes


def bucket_layout(cfg: ModelConfig) -> List[List[Tuple[str, Tuple[int, ...]]]]:
    """Greedy contiguous grouping of parameter tensors into n_buckets.

    Mirrors tensor fusion: contiguous forward-order spans with roughly
    equal parameter mass (the DDP-style fusion the schedulers re-cut).
    """
    shapes = param_shapes(cfg)
    sizes = [math.prod(s) for _, s in shapes]
    total = sum(sizes)
    target = total / cfg.n_buckets
    buckets: List[List[Tuple[str, Tuple[int, ...]]]] = []
    cur: List[Tuple[str, Tuple[int, ...]]] = []
    acc = 0
    remaining_buckets = cfg.n_buckets
    for (name, shape), size in zip(shapes, sizes):
        cur.append((name, shape))
        acc += size
        if acc >= target and len(buckets) < cfg.n_buckets - 1:
            buckets.append(cur)
            cur = []
            acc = 0
            remaining_buckets -= 1
    if cur:
        buckets.append(cur)
    assert len(buckets) <= cfg.n_buckets
    return buckets


def bucket_sizes(cfg: ModelConfig) -> List[int]:
    return [sum(math.prod(s) for _, s in bucket) for bucket in bucket_layout(cfg)]


def unflatten(cfg: ModelConfig, buckets: List[jnp.ndarray]) -> dict:
    """Flat bucket vectors -> parameter dict."""
    layout = bucket_layout(cfg)
    assert len(buckets) == len(layout)
    params = {}
    for vec, bucket in zip(buckets, layout):
        off = 0
        for name, shape in bucket:
            size = 1
            for d in shape:
                size *= d
            params[name] = vec[off : off + size].reshape(shape)
            off += size
        assert off == vec.shape[0], f"bucket size mismatch: {off} vs {vec.shape[0]}"
    return params


def flatten_grads(cfg: ModelConfig, grads: dict) -> List[jnp.ndarray]:
    """Parameter-dict gradients -> flat bucket vectors."""
    layout = bucket_layout(cfg)
    out = []
    for bucket in layout:
        out.append(jnp.concatenate([grads[name].reshape(-1) for name, _ in bucket]))
    return out


def init_params(cfg: ModelConfig, seed: int = 7) -> List[jnp.ndarray]:
    """Initial flat bucket vectors (scaled-normal init)."""
    key = jax.random.PRNGKey(seed)
    layout = bucket_layout(cfg)
    buckets = []
    for bucket in layout:
        parts = []
        for name, shape in bucket:
            key, sub = jax.random.split(key)
            size = 1
            for d in shape:
                size *= d
            if name.endswith(("_b", "ln1_b", "ln2_b", "lnf_b", "qkv_b")):
                parts.append(jnp.zeros((size,), jnp.float32))
            elif "ln" in name and name.endswith("_g"):
                parts.append(jnp.ones((size,), jnp.float32))
            else:
                fan_in = shape[0] if len(shape) > 1 else shape[0]
                std = 0.02 if name in ("wte", "wpe") else 1.0 / jnp.sqrt(fan_in)
                parts.append(std * jax.random.normal(sub, (size,), jnp.float32))
        buckets.append(jnp.concatenate(parts))
    return buckets


def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def forward(cfg: ModelConfig, params: dict, tokens_in: jnp.ndarray) -> jnp.ndarray:
    """Logits [batch, seq, vocab] for input tokens [batch, seq]."""
    b, s = tokens_in.shape
    x = params["wte"][tokens_in] + params["wpe"][None, :s, :]
    for i in range(cfg.n_layers):
        h = _layernorm(x, params[f"h{i}.ln1_g"], params[f"h{i}.ln1_b"])
        qkv = h @ params[f"h{i}.qkv_w"] + params[f"h{i}.qkv_b"]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)

        attn = attention(heads(q), heads(k), heads(v), True)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + attn @ params[f"h{i}.proj_w"] + params[f"h{i}.proj_b"]

        h = _layernorm(x, params[f"h{i}.ln2_g"], params[f"h{i}.ln2_b"])
        h = jax.nn.gelu(h @ params[f"h{i}.fc_w"] + params[f"h{i}.fc_b"])
        x = x + h @ params[f"h{i}.out_w"] + params[f"h{i}.out_b"]
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["head"]


def loss_fn(cfg: ModelConfig, buckets: List[jnp.ndarray], tokens: jnp.ndarray):
    """Mean next-token cross-entropy. tokens: [batch, seq+1] int32."""
    params = unflatten(cfg, buckets)
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def make_train_step(cfg: ModelConfig):
    """(b0..bK-1, tokens) -> (loss, g0..gK-1)."""

    def train_step(*args):
        buckets = list(args[:-1])
        tokens = args[-1]

        def f(bs):
            return loss_fn(cfg, bs, tokens)

        loss, grads = jax.value_and_grad(f)(buckets)
        return (loss, *grads)

    return train_step


def make_apply_update(cfg: ModelConfig):
    """(b*, g*, m*, lr, scale) -> (b'*, m'*) via the Pallas update kernel."""
    k = len(bucket_sizes(cfg))
    beta = jnp.asarray([0.9], jnp.float32)

    def apply_update(*args):
        buckets = args[:k]
        grads = args[k : 2 * k]
        momenta = args[2 * k : 3 * k]
        lr = args[3 * k]
        scale = args[3 * k + 1]
        new_b = []
        new_m = []
        for p, g, m in zip(buckets, grads, momenta):
            pn, mn = sgd_update(p, g, m, lr, scale, beta)
            new_b.append(pn)
            new_m.append(mn)
        return (*new_b, *new_m)

    return apply_update


def make_grad_reduce(cfg: ModelConfig, workers: int):
    """(stacked g0 [W,n0], ..., stacked gK-1) -> (mean g0, ...)."""
    del cfg

    def grad_reduce(*stacked):
        return tuple(bucket_reduce(g) for g in stacked)

    return grad_reduce
