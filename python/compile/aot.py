"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts + manifest.

HLO *text* is the interchange format — the image's xla_extension 0.5.1
rejects jax>=0.5 serialized protos (64-bit instruction ids); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py.

Usage (from python/):
    python -m compile.aot --out ../artifacts [--d-model 128 --n-layers 2
        --vocab 512 --seq 128 --batch 8 --n-buckets 4 --workers 4]

Emits into the output directory:
    train_step.hlo.txt, apply_update.hlo.txt, grad_reduce.hlo.txt,
    init_b{i}.bin (little-endian f32 initial bucket values),
    manifest.toml (signatures; parsed by rust/src/runtime/manifest.rs).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_str(name: str, dtype: str, dims) -> str:
    d = "x".join(str(x) for x in dims) if dims else "1"
    return f"{name}:{dtype}:{d}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-buckets", type=int, default=4)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    cfg = M.ModelConfig(
        vocab=args.vocab,
        seq=args.seq,
        d_model=args.d_model,
        n_layers=args.n_layers,
        n_heads=args.n_heads,
        batch=args.batch,
        n_buckets=args.n_buckets,
    )
    os.makedirs(args.out, exist_ok=True)
    sizes = M.bucket_sizes(cfg)
    k = len(sizes)
    total = sum(sizes)
    print(f"model: d={cfg.d_model} L={cfg.n_layers} vocab={cfg.vocab} "
          f"seq={cfg.seq} batch={cfg.batch} -> {total} params in {k} buckets {sizes}")

    bspecs = [jax.ShapeDtypeStruct((s,), jnp.float32) for s in sizes]
    tokens_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)
    scalar = jax.ShapeDtypeStruct((1,), jnp.float32)

    manifest = ["[meta]"]
    manifest.append('model = "small_transformer"')
    for key, val in [
        ("n_buckets", k),
        ("vocab", cfg.vocab),
        ("seq", cfg.seq),
        ("batch", cfg.batch),
        ("d_model", cfg.d_model),
        ("n_layers", cfg.n_layers),
        ("workers", args.workers),
        ("total_params", total),
    ]:
        manifest.append(f"{key} = {val}")

    # ---- initial parameters (binary f32 little-endian) ----
    init = M.init_params(cfg, seed=args.seed)
    init_files = []
    for i, vec in enumerate(init):
        import numpy as np

        fname = f"init_b{i}.bin"
        np.asarray(vec, dtype="<f4").tofile(os.path.join(args.out, fname))
        init_files.append(fname)
    manifest.append(f'init_files = "{";".join(init_files)}"')

    # ---- train_step ----
    train_step = M.make_train_step(cfg)
    lowered = jax.jit(train_step).lower(*bspecs, tokens_spec)
    text = to_hlo_text(lowered)
    with open(os.path.join(args.out, "train_step.hlo.txt"), "w") as f:
        f.write(text)
    print(f"train_step: {len(text)} chars of HLO")
    ins = ";".join(
        [spec_str(f"b{i}", "f32", (s,)) for i, s in enumerate(sizes)]
        + [spec_str("tokens", "i32", (cfg.batch, cfg.seq + 1))]
    )
    outs = ";".join(
        [spec_str("loss", "f32", ())]
        + [spec_str(f"g{i}", "f32", (s,)) for i, s in enumerate(sizes)]
    )
    manifest += [
        "[exe.train_step]",
        'file = "train_step.hlo.txt"',
        f'inputs = "{ins}"',
        f'outputs = "{outs}"',
    ]

    # ---- apply_update ----
    apply_update = M.make_apply_update(cfg)
    lowered = jax.jit(apply_update).lower(*bspecs, *bspecs, *bspecs, scalar, scalar)
    text = to_hlo_text(lowered)
    with open(os.path.join(args.out, "apply_update.hlo.txt"), "w") as f:
        f.write(text)
    print(f"apply_update: {len(text)} chars of HLO")
    ins = ";".join(
        [spec_str(f"b{i}", "f32", (s,)) for i, s in enumerate(sizes)]
        + [spec_str(f"g{i}", "f32", (s,)) for i, s in enumerate(sizes)]
        + [spec_str(f"m{i}", "f32", (s,)) for i, s in enumerate(sizes)]
        + [spec_str("lr", "f32", (1,)), spec_str("scale", "f32", (1,))]
    )
    outs = ";".join(
        [spec_str(f"b{i}", "f32", (s,)) for i, s in enumerate(sizes)]
        + [spec_str(f"m{i}", "f32", (s,)) for i, s in enumerate(sizes)]
    )
    manifest += [
        "[exe.apply_update]",
        'file = "apply_update.hlo.txt"',
        f'inputs = "{ins}"',
        f'outputs = "{outs}"',
    ]

    # ---- grad_reduce ----
    grad_reduce = M.make_grad_reduce(cfg, args.workers)
    stacked = [jax.ShapeDtypeStruct((args.workers, s), jnp.float32) for s in sizes]
    lowered = jax.jit(grad_reduce).lower(*stacked)
    text = to_hlo_text(lowered)
    with open(os.path.join(args.out, "grad_reduce.hlo.txt"), "w") as f:
        f.write(text)
    print(f"grad_reduce: {len(text)} chars of HLO")
    ins = ";".join(
        spec_str(f"g{i}", "f32", (args.workers, s)) for i, s in enumerate(sizes)
    )
    outs = ";".join(spec_str(f"r{i}", "f32", (s,)) for i, s in enumerate(sizes))
    manifest += [
        "[exe.grad_reduce]",
        'file = "grad_reduce.hlo.txt"',
        f'inputs = "{ins}"',
        f'outputs = "{outs}"',
    ]

    with open(os.path.join(args.out, "manifest.toml"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {k} buckets to {args.out}/manifest.toml")


if __name__ == "__main__":
    main()
