//! Quickstart: simulate all four scheduling schemes on the paper's VGG-19
//! workload (16 GPUs, 40 Gbps) and print the comparison table plus a
//! steady-state Gantt chart of DeFT's schedule.
//!
//! Run: `cargo run --release --example quickstart`

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::ClusterEnv;
use deft::metrics::{gantt_steady, Table};

fn main() {
    let workload = workload_by_name("vgg19");
    let env = ClusterEnv::paper_testbed();
    println!(
        "workload = {} ({} params, CR = {:.2} at 16 GPUs / 40 Gbps)\n",
        workload.name,
        workload.total_params(),
        workload.coverage_rate_ref()
    );

    let mut table = Table::new(&[
        "scheme",
        "iter time",
        "bubble %",
        "throughput (samples/s)",
        "updates/iter",
        "speedup vs ddp",
    ]);
    let mut ddp = None;
    let mut deft_result = None;
    let mut schemes = Scheme::ALL.to_vec();
    schemes.push(Scheme::DeftNoMultilink);
    for scheme in schemes {
        let r = run_pipeline(&workload, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 50);
        let t = r.sim.steady_iter_time;
        if scheme == Scheme::PytorchDdp {
            ddp = Some(t);
        }
        table.row(&[
            scheme.name().into(),
            format!("{t}"),
            format!("{:.1}", r.sim.bubble_ratio() * 100.0),
            format!("{:.0}", r.sim.throughput(workload.batch_size, env.workers)),
            format!("{:.2}", r.schedule.update_frequency()),
            ddp.map(|d| format!("{:.2}x", d.ratio(t))).unwrap_or("-".into()),
        ]);
        if scheme == Scheme::Deft {
            deft_result = Some(r);
        }
    }
    println!("{}", table.render());

    let deft = deft_result.expect("deft ran");
    println!(
        "DeFT steady-state cycle: {} iterations, {} updates, batch multipliers {:?}\n",
        deft.schedule.cycle.len(),
        deft.schedule.updates_per_cycle,
        deft.schedule.batch_multipliers
    );
    println!("DeFT schedule (one steady-state window):");
    println!("{}", gantt_steady(&deft.sim, deft.schedule.cycle.len(), 110));
}
