//! Quickstart: simulate all four scheduling schemes on the paper's VGG-19
//! workload (16 GPUs, 40 Gbps) and print the comparison table plus a
//! steady-state Gantt chart of DeFT's schedule.
//!
//! Run: `cargo run --release --example quickstart`

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::{ClusterEnv, Codec, LinkId};
use deft::metrics::{gantt_steady, Table};

fn main() {
    let workload = workload_by_name("vgg19").expect("workload");
    let env = ClusterEnv::paper_testbed();
    println!(
        "workload = {} ({} params, CR = {:.2} at 16 GPUs / 40 Gbps)\n",
        workload.name,
        workload.total_params(),
        workload.coverage_rate_ref()
    );

    let mut table = Table::new(&[
        "scheme",
        "iter time",
        "bubble %",
        "throughput (samples/s)",
        "updates/iter",
        "speedup vs ddp",
    ]);
    let mut ddp = None;
    let mut deft_result = None;
    let mut schemes = Scheme::ALL.to_vec();
    schemes.push(Scheme::DeftNoMultilink);
    for scheme in schemes {
        let r = run_pipeline(&workload, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 50)
            .expect("pipeline");
        let t = r.sim.steady_iter_time;
        if scheme == Scheme::PytorchDdp {
            ddp = Some(t);
        }
        table.row(&[
            scheme.name().into(),
            format!("{t}"),
            format!("{:.1}", r.sim.bubble_ratio() * 100.0),
            format!("{:.0}", r.sim.throughput(workload.batch_size, env.workers)),
            format!("{:.2}", r.schedule.update_frequency()),
            ddp.map(|d| format!("{:.2}x", d.ratio(t))).unwrap_or("-".into()),
        ]);
        if scheme == Scheme::Deft {
            deft_result = Some(r);
        }
    }
    println!("{}", table.render());

    let deft = deft_result.expect("deft ran");
    println!(
        "DeFT steady-state cycle: {} iterations, {} updates, batch multipliers {:?}\n",
        deft.schedule.cycle.len(),
        deft.schedule.updates_per_cycle,
        deft.schedule.batch_multipliers
    );
    println!("DeFT schedule (one steady-state window):");
    println!("{}", gantt_steady(&deft.sim, deft.schedule.cycle.len(), 110));

    // Per-link compression: the codec-aware ClusterEnv builder attaches
    // an fp16 codec to the slow gloo link — half the bytes on the wire,
    // a rounding error far inside the Preserver's ε band.
    let fp16_env = ClusterEnv::paper_testbed().with_codec(LinkId(1), Codec::Fp16);
    let fp16 = run_pipeline(&workload, Scheme::Deft, &fp16_env, PAPER_PARTITION, PAPER_DDP_MB, 50)
        .expect("pipeline");
    let gloo = &fp16.sim.link_traffic[1];
    println!(
        "With fp16 on gloo: iter {} (raw links {}), gloo ships {:.0} MB of {:.0} MB raw, \
         encode overhead {}",
        fp16.sim.steady_iter_time,
        deft.sim.steady_iter_time,
        gloo.wire_bytes as f64 / 1e6,
        gloo.raw_bytes as f64 / 1e6,
        gloo.encode,
    );
}
