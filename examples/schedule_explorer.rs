//! Schedule explorer: render the per-scheme bucket scheduling timelines
//! of paper Figs. 11–13 for any workload and link topology, plus the
//! profiler round-trip (raw operator trace → bucket reconstruction →
//! schedule) and a per-link busy/bubble table.
//!
//! Run: `cargo run --release --example schedule_explorer -- [workload] [--links <preset>]`
//! (workload ∈ resnet101 | vgg19 | gpt2; default vgg19;
//!  preset ∈ paper-2link | single-nic | nvlink-ib-tcp; default paper-2link)

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::{LinkId, LinkPreset};
use deft::metrics::{gantt_steady, Table};
use deft::models::BucketProfile;
use deft::profiler::{generate_trace, reconstruct, TraceOptions};
use deft::sched::feature_matrix;
use deft::sim::{SimResult, StreamId};

fn parse_args() -> (String, LinkPreset) {
    let mut workload = "vgg19".to_string();
    let mut preset = LinkPreset::Paper2Link;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let looked_up = if let Some(v) = a.strip_prefix("--links=") {
            Some(v.to_string())
        } else if a == "--links" {
            Some(args.next().expect("--links needs a preset name"))
        } else {
            workload = a;
            None
        };
        if let Some(name) = looked_up {
            preset = LinkPreset::parse(&name).unwrap_or_else(|| {
                panic!(
                    "unknown links preset `{name}` (known: {})",
                    LinkPreset::ALL
                        .iter()
                        .map(|p| p.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            });
        }
    }
    (workload, preset)
}

/// Per-link busy/bubble table computed from the simulation timeline.
fn link_table(sim: &SimResult) -> String {
    let mut t = Table::new(&["link", "busy", "bubbles", "utilization"]);
    for (k, name) in sim.link_names.iter().enumerate() {
        let stream = StreamId::Link(LinkId(k));
        let busy = sim.timeline.busy(stream);
        let bubbles = sim.timeline.bubbles(stream);
        let span = busy + bubbles;
        let util = if span.is_zero() {
            "-".to_string()
        } else {
            format!("{:.1}%", busy.ratio(span) * 100.0)
        };
        t.row(&[name.clone(), format!("{busy}"), format!("{bubbles}"), util]);
    }
    t.render()
}

fn main() {
    let (name, preset) = parse_args();
    let workload = workload_by_name(&name);
    let env = preset.env();

    println!("=== Table III: scheme feature matrix ===\n{}", feature_matrix());

    println!("=== Profiler round-trip (paper Fig. 8) ===");
    let topts = TraceOptions::uniform(&workload, 6);
    let (events, truth) = generate_trace(&workload, &topts);
    println!(
        "generated {} raw operator events across 4 threads",
        events.len()
    );
    let rec = reconstruct(&events);
    println!("bucket |   fwd(us) true/rec |   bwd(us) true/rec |  comm(us) true/rec");
    for (r, t) in rec.iter().zip(truth.buckets.iter()) {
        println!(
            "  #{:<3} | {:>8} / {:<8} | {:>8} / {:<8} | {:>8} / {:<8}",
            r.id + 1,
            t.0.as_us(),
            r.fwd.as_us(),
            t.1.as_us(),
            r.bwd.as_us(),
            t.2.as_us(),
            r.comm.as_us()
        );
    }

    // Feed the reconstructed profile straight into the schedulers.
    let buckets: Vec<BucketProfile> = rec
        .iter()
        .zip(workload.layers.chunks(workload.num_layers() / 6 + 1))
        .map(|(r, chunk)| BucketProfile {
            id: r.id,
            params: chunk.iter().map(|l| l.params).sum(),
            fwd: r.fwd,
            bwd: r.bwd,
            comm: r.comm,
        })
        .collect();
    let _ = buckets; // (the pipeline below re-partitions per scheme)

    println!(
        "\n=== Scheduling orders (paper Figs. 11-13) for {} on {} ({}) ===",
        workload.name,
        preset.name(),
        env.link_names().join("+")
    );
    let mut schemes = Scheme::ALL.to_vec();
    schemes.push(Scheme::DeftNoMultilink);
    for scheme in schemes {
        let r = run_pipeline(&workload, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 40);
        println!(
            "\n--- {} ({} buckets, iter {} | bubbles {:.1}%) ---",
            scheme.name(),
            r.buckets.len(),
            r.sim.steady_iter_time,
            r.sim.bubble_ratio() * 100.0
        );
        println!("{}", gantt_steady(&r.sim, r.schedule.cycle.len(), 110));
        println!("{}", link_table(&r.sim));
    }
}
