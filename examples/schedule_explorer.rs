//! Schedule explorer: render the per-scheme bucket scheduling timelines
//! of paper Figs. 11–13 for any workload, plus the profiler round-trip
//! (raw operator trace → bucket reconstruction → schedule).
//!
//! Run: `cargo run --release --example schedule_explorer -- [workload]`
//! (workload ∈ resnet101 | vgg19 | gpt2; default vgg19)

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::ClusterEnv;
use deft::metrics::gantt_steady;
use deft::models::BucketProfile;
use deft::profiler::{generate_trace, reconstruct, TraceOptions};
use deft::sched::feature_matrix;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "vgg19".into());
    let workload = workload_by_name(&name);
    let env = ClusterEnv::paper_testbed();

    println!("=== Table III: scheme feature matrix ===\n{}", feature_matrix());

    println!("=== Profiler round-trip (paper Fig. 8) ===");
    let topts = TraceOptions::uniform(&workload, 6);
    let (events, truth) = generate_trace(&workload, &topts);
    println!(
        "generated {} raw operator events across 4 threads",
        events.len()
    );
    let rec = reconstruct(&events);
    println!("bucket |   fwd(us) true/rec |   bwd(us) true/rec |  comm(us) true/rec");
    for (r, t) in rec.iter().zip(truth.buckets.iter()) {
        println!(
            "  #{:<3} | {:>8} / {:<8} | {:>8} / {:<8} | {:>8} / {:<8}",
            r.id + 1,
            t.0.as_us(),
            r.fwd.as_us(),
            t.1.as_us(),
            r.bwd.as_us(),
            t.2.as_us(),
            r.comm.as_us()
        );
    }

    // Feed the reconstructed profile straight into the schedulers.
    let buckets: Vec<BucketProfile> = rec
        .iter()
        .zip(workload.layers.chunks(workload.num_layers() / 6 + 1))
        .map(|(r, chunk)| BucketProfile {
            id: r.id,
            params: chunk.iter().map(|l| l.params).sum(),
            fwd: r.fwd,
            bwd: r.bwd,
            comm: r.comm,
        })
        .collect();
    let _ = buckets; // (the pipeline below re-partitions per scheme)

    println!("\n=== Scheduling orders (paper Figs. 11-13) for {} ===", workload.name);
    let mut schemes = Scheme::ALL.to_vec();
    schemes.push(Scheme::DeftNoMultilink);
    for scheme in schemes {
        let r = run_pipeline(&workload, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 40);
        println!(
            "\n--- {} ({} buckets, iter {} | bubbles {:.1}%) ---",
            scheme.name(),
            r.buckets.len(),
            r.sim.steady_iter_time,
            r.sim.bubble_ratio() * 100.0
        );
        println!("{}", gantt_steady(&r.sim, r.schedule.cycle.len(), 110));
    }
}
