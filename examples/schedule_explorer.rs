//! Schedule explorer: render the per-scheme bucket scheduling timelines
//! of paper Figs. 11–13 for any workload and link topology, plus the
//! profiler round-trip (raw operator trace → bucket reconstruction →
//! schedule) and a per-link busy/bubble table.
//!
//! Run: `cargo run --release --example schedule_explorer -- [workload]
//!        [--links <preset>] [--ranks-per-node <n>] [--codec <link>=<codec>]
//!        [--contention-model <pairwise|kway>] [--lint [--lint-json <path>]]`
//! (workload ∈ resnet101 | vgg19 | gpt2; default vgg19;
//!  preset ∈ paper-2link | single-nic | nvlink-ib-tcp; default paper-2link;
//!  --ranks-per-node > 1 applies a hierarchical topology with link 0 as
//!  the intra-node segment and link 1 as its cross-node fabric;
//!  --codec attaches a compression codec — raw | fp16 | rank<k> — to a
//!  registry link by name, e.g. `--codec tcp=fp16`; repeatable;
//!  --contention-model selects how shared-NIC contention is priced —
//!  aggregate k-way sharing (default) or the legacy pairwise rule;
//!  --lint skips the timelines and instead runs the static verifier
//!  (`deft::analysis`) over the full model-zoo × preset × topology ×
//!  scheme grid, printing one status row per plan and exiting non-zero
//!  if any plan carries an error diagnostic; --lint-json additionally
//!  writes every diagnostic as a JSON line tagged with its grid cell)

use deft::bench::{
    partition_for, run_pipeline, scheduler_for, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION,
};
use deft::config::Scheme;
use deft::links::{Codec, ContentionModel, LinkId, LinkPreset, Topology};
use deft::metrics::{gantt_steady, link_table};
use deft::models::BucketProfile;
use deft::profiler::{generate_trace, reconstruct, TraceOptions};
use deft::sched::feature_matrix;

fn parse_args() -> (String, LinkPreset, usize, Vec<(String, Codec)>, ContentionModel) {
    let mut workload = "vgg19".to_string();
    let mut preset = LinkPreset::Paper2Link;
    let mut ranks_per_node = 1usize;
    let mut codecs: Vec<(String, Codec)> = Vec::new();
    let mut contention = ContentionModel::default();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let looked_up = if a == "--lint" {
            let mut lint_json: Option<String> = None;
            while let Some(rest) = args.next() {
                if let Some(v) = rest.strip_prefix("--lint-json=") {
                    lint_json = Some(v.to_string());
                } else if rest == "--lint-json" {
                    lint_json = Some(args.next().expect("--lint-json needs a path"));
                } else {
                    panic!("--lint takes only --lint-json <path>, got `{rest}`");
                }
            }
            run_lint_grid(lint_json.as_deref())
        } else if let Some(v) = a.strip_prefix("--links=") {
            Some(v.to_string())
        } else if a == "--links" {
            Some(args.next().expect("--links needs a preset name"))
        } else if let Some(v) = a.strip_prefix("--ranks-per-node=") {
            ranks_per_node = v.parse().expect("--ranks-per-node needs an integer");
            None
        } else if a == "--ranks-per-node" {
            let v = args.next().expect("--ranks-per-node needs an integer");
            ranks_per_node = v.parse().expect("--ranks-per-node needs an integer");
            None
        } else if let Some(v) = a.strip_prefix("--codec=") {
            codecs.push(parse_codec_arg(v));
            None
        } else if a == "--codec" {
            let v = args.next().expect("--codec needs <link>=<codec>");
            codecs.push(parse_codec_arg(&v));
            None
        } else if let Some(v) = a.strip_prefix("--contention-model=") {
            contention = parse_contention_arg(v);
            None
        } else if a == "--contention-model" {
            let v = args.next().expect("--contention-model needs pairwise|kway");
            contention = parse_contention_arg(&v);
            None
        } else {
            workload = a;
            None
        };
        if let Some(name) = looked_up {
            preset = LinkPreset::parse(&name).unwrap_or_else(|| {
                panic!(
                    "unknown links preset `{name}` (known: {})",
                    LinkPreset::ALL
                        .iter()
                        .map(|p| p.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            });
        }
    }
    (workload, preset, ranks_per_node, codecs, contention)
}

fn parse_codec_arg(spec: &str) -> (String, Codec) {
    let (link, codec) = spec
        .split_once('=')
        .unwrap_or_else(|| panic!("--codec needs <link>=<codec>, got `{spec}`"));
    let codec = Codec::parse(codec)
        .unwrap_or_else(|| panic!("unknown codec `{codec}` (known: raw | fp16 | rank<k>)"));
    (link.to_string(), codec)
}

fn parse_contention_arg(name: &str) -> ContentionModel {
    ContentionModel::parse(name)
        .unwrap_or_else(|| panic!("unknown contention model `{name}` (known: pairwise | kway)"))
}

/// `--lint`: prove every plan the four schedulers emit over the full
/// model-zoo × link-preset × topology grid sound under the static
/// verifier, without running the simulator. One status row per plan;
/// every diagnostic (errors *and* warnings) goes to `--lint-json` as a
/// JSON line tagged with its grid cell. Exits 1 iff any plan carries an
/// error-severity diagnostic — the CI gate keys off the exit code.
fn run_lint_grid(lint_json: Option<&str>) -> ! {
    use deft::analysis::{lint_plan, LintOptions};
    use std::fmt::Write as _;

    let workloads = ["resnet101", "vgg19", "gpt2", "llama2"];
    let mut schemes = Scheme::ALL.to_vec();
    schemes.push(Scheme::DeftNoMultilink);
    let opts = LintOptions::default();
    let (mut jsonl, mut plans, mut skipped) = (String::new(), 0usize, 0usize);
    let (mut errors, mut warnings) = (0usize, 0usize);
    println!("stat workload   preset       topo  scheme             diagnostics");
    for wname in workloads {
        let workload = workload_by_name(wname).expect("zoo workload");
        for preset in LinkPreset::ALL {
            for topo in ["flat", "hier8"] {
                let mut env = preset.env();
                if topo == "hier8" {
                    env = env.with_topology(Topology::hierarchical(8, LinkId(0), LinkId(1)));
                }
                for &scheme in &schemes {
                    let buckets = match partition_for(
                        &workload, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB,
                    ) {
                        Ok(b) => b,
                        Err(e) => {
                            skipped += 1;
                            println!(
                                "skip {wname:10} {:12} {topo:5} {:18} partition: {e:#}",
                                preset.name(),
                                scheme.name()
                            );
                            continue;
                        }
                    };
                    let schedule = scheduler_for(scheme, true, &env).schedule(&buckets);
                    let report = lint_plan(&schedule, &buckets, &env, &opts);
                    plans += 1;
                    errors += report.error_count();
                    warnings += report.warning_count();
                    for d in &report.diagnostics {
                        writeln!(
                            jsonl,
                            "{{\"workload\":\"{wname}\",\"preset\":\"{}\",\"topology\":\"{topo}\",\"scheme\":\"{}\",{}}}",
                            preset.name(),
                            scheme.name(),
                            d.to_json_fields()
                        )
                        .expect("string write");
                    }
                    println!(
                        "{:4} {wname:10} {:12} {topo:5} {:18} {} error(s), {} warning(s)",
                        if report.is_clean() { "ok" } else { "FAIL" },
                        preset.name(),
                        scheme.name(),
                        report.error_count(),
                        report.warning_count()
                    );
                    if !report.is_clean() {
                        for line in report.render_text().lines() {
                            println!("     {line}");
                        }
                    }
                }
            }
        }
    }
    if let Some(path) = lint_json {
        std::fs::write(path, &jsonl)
            .unwrap_or_else(|e| panic!("writing lint report `{path}`: {e}"));
        println!("wrote diagnostics to {path}");
    }
    println!(
        "lint grid: {plans} plan(s) linted, {skipped} skipped, {errors} error(s), {warnings} warning(s)"
    );
    std::process::exit(i32::from(errors > 0));
}

fn main() {
    let (name, preset, ranks_per_node, codecs, contention) = parse_args();
    let workload = workload_by_name(&name).unwrap_or_else(|e| panic!("{e:#}"));
    let mut env = preset.env().with_contention_model(contention);
    if ranks_per_node > 1 {
        env = env.with_topology(Topology::hierarchical(ranks_per_node, LinkId(0), LinkId(1)));
    }
    for (link_name, codec) in &codecs {
        let id = env.link(link_name).unwrap_or_else(|| {
            panic!(
                "--codec: unknown link `{link_name}` (registry: {})",
                env.link_names().join(", ")
            )
        });
        env = env.with_codec(id, *codec);
    }
    if env.has_lossy_codec() {
        println!(
            "codecs: {}\n",
            env.links
                .iter()
                .map(|l| format!("{}={}", l.name, l.codec.name()))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }

    println!("=== Table III: scheme feature matrix ===\n{}", feature_matrix());

    println!("=== Profiler round-trip (paper Fig. 8) ===");
    let topts = TraceOptions::uniform(&workload, 6);
    let (events, truth) = generate_trace(&workload, &topts);
    println!(
        "generated {} raw operator events across 4 threads",
        events.len()
    );
    let rec = reconstruct(&events);
    println!("bucket |   fwd(us) true/rec |   bwd(us) true/rec |  comm(us) true/rec");
    for (r, t) in rec.iter().zip(truth.buckets.iter()) {
        println!(
            "  #{:<3} | {:>8} / {:<8} | {:>8} / {:<8} | {:>8} / {:<8}",
            r.id + 1,
            t.0.as_us(),
            r.fwd.as_us(),
            t.1.as_us(),
            r.bwd.as_us(),
            t.2.as_us(),
            r.comm.as_us()
        );
    }

    // Feed the reconstructed profile straight into the schedulers.
    let buckets: Vec<BucketProfile> = rec
        .iter()
        .zip(workload.layers.chunks(workload.num_layers() / 6 + 1))
        .map(|(r, chunk)| BucketProfile {
            id: r.id,
            params: chunk.iter().map(|l| l.params).sum(),
            fwd: r.fwd,
            bwd: r.bwd,
            comm: r.comm,
        })
        .collect();
    let _ = buckets; // (the pipeline below re-partitions per scheme)

    println!(
        "\n=== Scheduling orders (paper Figs. 11-13) for {} on {} ({}; contention: {}) ===",
        workload.name,
        preset.name(),
        env.link_names().join("+"),
        env.contention.name()
    );
    let mut schemes = Scheme::ALL.to_vec();
    schemes.push(Scheme::DeftNoMultilink);
    for scheme in schemes {
        let r = run_pipeline(&workload, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 40)
            .expect("pipeline");
        println!(
            "\n--- {} ({} buckets, iter {} | bubbles {:.1}%) ---",
            scheme.name(),
            r.buckets.len(),
            r.sim.steady_iter_time,
            r.sim.bubble_ratio() * 100.0
        );
        println!("{}", gantt_steady(&r.sim, r.schedule.cycle.len(), 110));
        println!("{}", link_table(&r.sim));
    }
}
