//! Schedule explorer: render the per-scheme bucket scheduling timelines
//! of paper Figs. 11–13 for any workload and link topology, plus the
//! profiler round-trip (raw operator trace → bucket reconstruction →
//! schedule) and a per-link busy/bubble table.
//!
//! Run: `cargo run --release --example schedule_explorer -- [workload]
//!        [--links <preset>] [--ranks-per-node <n>] [--codec <link>=<codec>]
//!        [--contention-model <pairwise|kway>]
//!        [--faults <scenario>] [--fault-seed <n>] [--fault-log <path>]
//!        [--replan]
//!        [--lint [--lint-json <path>]]
//!        [--sweep [--grid small|full] [--threads <n>] [--out <path>]
//!                 [--csv <path>] [--faults <scenario>]]
//!        [--serve]`
//! (workload ∈ resnet101 | vgg19 | gpt2; default vgg19;
//!  preset ∈ paper-2link | single-nic | nvlink-ib-tcp; default paper-2link;
//!  --ranks-per-node > 1 applies a hierarchical topology with link 0 as
//!  the intra-node segment and link 1 as its cross-node fabric;
//!  --codec attaches a compression codec — raw | fp16 | rank<k> — to a
//!  registry link by name, e.g. `--codec tcp=fp16`; repeatable;
//!  --contention-model selects how shared-NIC contention is priced —
//!  aggregate k-way sharing (default) or the legacy pairwise rule;
//!  --faults injects a named fault scenario (straggler | flap | elastic
//!  | mixed — see docs/faults.md) into every simulation, printing the
//!  degraded iteration time next to the healthy one; --fault-seed
//!  overrides the scenario's jitter seed; --fault-log writes every
//!  recorded fault event as a JSON line;
//!  --replan closes the loop on drift: a rejected drift re-gate
//!  re-solves the §III.D knapsacks against measured link capacities
//!  instead of falling straight back to the raw plan (docs/replan.md) —
//!  it switches the DeFT legs of --sweep, and adds a `deft+replan`
//!  lifecycle row per faulted --lint cell;
//!  --lint skips the timelines and instead runs the static verifier
//!  (`deft::analysis`) over the full model-zoo × preset × topology ×
//!  scheme grid, printing one status row per plan and exiting non-zero
//!  if any plan carries an error diagnostic; --lint-json additionally
//!  writes every diagnostic as a JSON line tagged with its grid cell.
//!  With --faults, the lint grid also carries the scenario's worst-case
//!  link degradation as a capacity envelope — plans that only fit
//!  healthy links pick up DEFT-W004 warnings — and each grid cell runs
//!  a short faulted simulation on both engines, asserting they agree
//!  bit-for-bit and feeding --fault-log;
//!  --sweep runs the batch sweep engine (`deft::sweep`) over the named
//!  grid across a thread pool, printing one winner row per cell,
//!  writing results as JSON lines (--out) and a summary CSV (--csv),
//!  and exiting non-zero if any cell errors — `--faults` inside the
//!  sub-command pins the grid's fault axis to one scenario;
//!  --serve starts the long-running capacity planner: line-delimited
//!  JSON queries on stdin, memoized cell answers on stdout — see
//!  docs/sweeps.md for the protocol)

use deft::bench::{
    partition_for, run_pipeline, scheduler_for, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION,
};
use deft::config::Scheme;
use deft::faults::FaultSpec;
use deft::links::{Codec, ContentionModel, LinkId, LinkPreset, Topology};
use deft::metrics::{gantt_steady, link_table};
use deft::models::BucketProfile;
use deft::profiler::{generate_trace, reconstruct, TraceOptions};
use deft::sched::feature_matrix;
use deft::sim::{simulate_faulted, simulate_scan_faulted, SimOptions};

struct Args {
    workload: String,
    preset: LinkPreset,
    ranks_per_node: usize,
    codecs: Vec<(String, Codec)>,
    contention: ContentionModel,
    faults: Option<String>,
    fault_seed: Option<u64>,
    fault_log: Option<String>,
}

fn parse_args() -> Args {
    let mut workload = "vgg19".to_string();
    let mut preset = LinkPreset::Paper2Link;
    let mut ranks_per_node = 1usize;
    let mut codecs: Vec<(String, Codec)> = Vec::new();
    let mut contention = ContentionModel::default();
    let mut faults: Option<String> = None;
    let mut fault_seed: Option<u64> = None;
    let mut fault_log: Option<String> = None;
    let mut replan = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let looked_up = if a == "--lint" {
            let mut lint_json: Option<String> = None;
            while let Some(rest) = args.next() {
                if let Some(v) = rest.strip_prefix("--lint-json=") {
                    lint_json = Some(v.to_string());
                } else if rest == "--lint-json" {
                    lint_json = Some(args.next().expect("--lint-json needs a path"));
                } else if let Some(v) = rest.strip_prefix("--fault-log=") {
                    fault_log = Some(v.to_string());
                } else if rest == "--fault-log" {
                    fault_log = Some(args.next().expect("--fault-log needs a path"));
                } else if rest == "--replan" {
                    replan = true;
                } else {
                    panic!(
                        "--lint takes only --lint-json <path> / --fault-log <path> / --replan, \
                         got `{rest}`"
                    );
                }
            }
            run_lint_grid(
                lint_json.as_deref(),
                faults.as_deref(),
                fault_seed,
                fault_log.as_deref(),
                replan,
            )
        } else if a == "--sweep" {
            let mut grid_name = "small".to_string();
            let mut threads = 4usize;
            let mut out: Option<String> = None;
            let mut csv: Option<String> = None;
            let mut sweep_faults = faults.clone();
            while let Some(rest) = args.next() {
                if let Some(v) = rest.strip_prefix("--grid=") {
                    grid_name = v.to_string();
                } else if rest == "--grid" {
                    grid_name = args.next().expect("--grid needs small|full");
                } else if let Some(v) = rest.strip_prefix("--threads=") {
                    threads = v.parse().expect("--threads needs an integer");
                } else if rest == "--threads" {
                    let v = args.next().expect("--threads needs an integer");
                    threads = v.parse().expect("--threads needs an integer");
                } else if let Some(v) = rest.strip_prefix("--out=") {
                    out = Some(v.to_string());
                } else if rest == "--out" {
                    out = Some(args.next().expect("--out needs a path"));
                } else if let Some(v) = rest.strip_prefix("--faults=") {
                    sweep_faults = Some(parse_faults_arg(v));
                } else if rest == "--faults" {
                    let v = args.next().expect("--faults needs a scenario name");
                    sweep_faults = Some(parse_faults_arg(&v));
                } else if let Some(v) = rest.strip_prefix("--csv=") {
                    csv = Some(v.to_string());
                } else if rest == "--csv" {
                    csv = Some(args.next().expect("--csv needs a path"));
                } else if rest == "--replan" {
                    replan = true;
                } else {
                    panic!(
                        "--sweep takes only --grid small|full / --threads N / --out FILE / \
                         --csv FILE / --faults NAME / --replan, got `{rest}`"
                    );
                }
            }
            run_sweep(
                &grid_name,
                threads,
                out.as_deref(),
                csv.as_deref(),
                sweep_faults.as_deref(),
                replan,
            )
        } else if a == "--serve" {
            run_serve()
        } else if let Some(v) = a.strip_prefix("--faults=") {
            faults = Some(parse_faults_arg(v));
            None
        } else if a == "--faults" {
            let v = args.next().expect("--faults needs a scenario name");
            faults = Some(parse_faults_arg(&v));
            None
        } else if let Some(v) = a.strip_prefix("--fault-seed=") {
            fault_seed = Some(v.parse().expect("--fault-seed needs an integer"));
            None
        } else if a == "--fault-seed" {
            let v = args.next().expect("--fault-seed needs an integer");
            fault_seed = Some(v.parse().expect("--fault-seed needs an integer"));
            None
        } else if let Some(v) = a.strip_prefix("--fault-log=") {
            fault_log = Some(v.to_string());
            None
        } else if a == "--fault-log" {
            fault_log = Some(args.next().expect("--fault-log needs a path"));
            None
        } else if a == "--replan" {
            replan = true;
            None
        } else if let Some(v) = a.strip_prefix("--links=") {
            Some(v.to_string())
        } else if a == "--links" {
            Some(args.next().expect("--links needs a preset name"))
        } else if let Some(v) = a.strip_prefix("--ranks-per-node=") {
            ranks_per_node = v.parse().expect("--ranks-per-node needs an integer");
            None
        } else if a == "--ranks-per-node" {
            let v = args.next().expect("--ranks-per-node needs an integer");
            ranks_per_node = v.parse().expect("--ranks-per-node needs an integer");
            None
        } else if let Some(v) = a.strip_prefix("--codec=") {
            codecs.push(parse_codec_arg(v));
            None
        } else if a == "--codec" {
            let v = args.next().expect("--codec needs <link>=<codec>");
            codecs.push(parse_codec_arg(&v));
            None
        } else if let Some(v) = a.strip_prefix("--contention-model=") {
            contention = parse_contention_arg(v);
            None
        } else if a == "--contention-model" {
            let v = args.next().expect("--contention-model needs pairwise|kway");
            contention = parse_contention_arg(&v);
            None
        } else {
            workload = a;
            None
        };
        if let Some(name) = looked_up {
            preset = LinkPreset::parse(&name).unwrap_or_else(|| {
                panic!(
                    "unknown links preset `{name}` (known: {})",
                    LinkPreset::ALL
                        .iter()
                        .map(|p| p.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            });
        }
    }
    Args {
        workload,
        preset,
        ranks_per_node,
        codecs,
        contention,
        faults,
        fault_seed,
        fault_log,
    }
}

fn parse_faults_arg(name: &str) -> String {
    // Resolve against a placeholder worker count purely to validate the
    // name early; real specs are rebuilt per environment.
    if FaultSpec::preset(name, 16).is_none() {
        panic!(
            "unknown fault scenario `{name}` (known: {})",
            FaultSpec::preset_names().join(" | ")
        );
    }
    name.to_string()
}

/// Resolve a named scenario against `workers`, with the optional
/// `--fault-seed` override applied.
fn fault_spec_for(scenario: &str, workers: usize, seed: Option<u64>) -> FaultSpec {
    let mut spec = FaultSpec::preset(scenario, workers).expect("validated scenario name");
    if let Some(s) = seed {
        spec.seed = s;
    }
    spec
}

fn parse_codec_arg(spec: &str) -> (String, Codec) {
    let (link, codec) = spec
        .split_once('=')
        .unwrap_or_else(|| panic!("--codec needs <link>=<codec>, got `{spec}`"));
    let codec = Codec::parse(codec)
        .unwrap_or_else(|| panic!("unknown codec `{codec}` (known: raw | fp16 | rank<k>)"));
    (link.to_string(), codec)
}

fn parse_contention_arg(name: &str) -> ContentionModel {
    ContentionModel::parse(name)
        .unwrap_or_else(|| panic!("unknown contention model `{name}` (known: pairwise | kway)"))
}

/// `--sweep`: fan the named grid across a thread pool of DES runs
/// (`deft::sweep::run_grid`), print one winner row per cell, stream the
/// full results as JSON lines / summary CSV, and exit non-zero iff any
/// cell errored — the CI smoke step keys off the exit code. Parallel
/// output is bit-for-bit identical to `--threads 1`. `--replan` lets
/// every DeFT leg re-plan on a rejected drift re-gate instead of
/// falling back raw (docs/replan.md).
fn run_sweep(
    grid_name: &str,
    threads: usize,
    out: Option<&str>,
    csv: Option<&str>,
    faults: Option<&str>,
    replan: bool,
) -> ! {
    use deft::sweep::{run_grid, summary_csv, to_jsonl, SweepGrid};
    let mut grid = match grid_name {
        "small" => SweepGrid::small(),
        "full" => SweepGrid::full(),
        other => panic!("--grid takes small|full, got `{other}`"),
    };
    if let Some(name) = faults {
        grid.faults = vec![Some(name.to_string())];
    }
    grid.replan = replan;
    let cells = grid.cells();
    eprintln!(
        "sweep: {} cell(s) ({grid_name} grid{}{}) across {threads} thread(s)...",
        cells.len(),
        faults.map(|f| format!(", faults `{f}`")).unwrap_or_default(),
        if replan { ", replan on" } else { "" }
    );
    let outcomes = run_grid(&grid, threads);
    let mut errors = 0usize;
    println!("stat cell                                                        winner         iter(us)   tts(us)  coverage");
    for o in &outcomes {
        match &o.result {
            Ok(r) => println!(
                "ok   {:59} {:14} {:>8} {:>9} {:>7.1}%",
                o.cell.key(),
                r.winner,
                r.iter_us,
                r.tts_us,
                r.coverage_ppm as f64 / 10_000.0
            ),
            Err(e) => {
                errors += 1;
                println!("FAIL {:59} {e}", o.cell.key());
            }
        }
    }
    if let Some(path) = out {
        std::fs::write(path, to_jsonl(&outcomes))
            .unwrap_or_else(|e| panic!("writing sweep results `{path}`: {e}"));
        println!("wrote {} JSONL line(s) to {path}", outcomes.len());
    }
    if let Some(path) = csv {
        std::fs::write(path, summary_csv(&outcomes))
            .unwrap_or_else(|e| panic!("writing sweep summary `{path}`: {e}"));
        println!("wrote summary CSV to {path}");
    }
    println!("sweep: {} cell(s), {errors} error(s)", outcomes.len());
    std::process::exit(i32::from(errors > 0));
}

/// `--serve`: the long-running capacity planner. Line-delimited JSON
/// queries on stdin, memoized cell answers on stdout (protocol in
/// docs/sweeps.md); ends on `quit`/`exit`/EOF.
fn run_serve() -> ! {
    let mut planner = deft::sweep::Planner::new();
    eprintln!(
        "capacity planner ready: one JSON query per line on stdin \
         (e.g. {{\"workload\": \"gpt2\", \"ranks_per_node\": 8}}); `quit` ends"
    );
    planner
        .serve(std::io::stdin().lock(), std::io::stdout().lock())
        .expect("planner I/O");
    eprintln!(
        "planner: {} cache hit(s), {} miss(es)",
        planner.hits(),
        planner.misses()
    );
    std::process::exit(0);
}

/// `--lint`: prove every plan the four schedulers emit over the full
/// model-zoo × link-preset × topology grid sound under the static
/// verifier, without running the simulator. One status row per plan;
/// every diagnostic (errors *and* warnings) goes to `--lint-json` as a
/// JSON line tagged with its grid cell. Exits 1 iff any plan carries an
/// error-severity diagnostic — the CI gate keys off the exit code.
///
/// With a `--faults` scenario the grid additionally (a) lints every plan
/// against the scenario's worst-case capacity envelope (DEFT-W004) and
/// (b) runs a short faulted simulation of every cell on both engines,
/// asserting bit-for-bit agreement; recorded fault events go to
/// `--fault-log` as JSON lines tagged with their cell.
///
/// `--replan` (with `--faults`) adds one `deft+replan` row per grid
/// cell: the full DeFT lifecycle with measured-drift re-planning on,
/// whose accepted schedule must itself lint clean — the CI fault grid
/// keys off that row staying error-free.
fn run_lint_grid(
    lint_json: Option<&str>,
    fault_scenario: Option<&str>,
    fault_seed: Option<u64>,
    fault_log: Option<&str>,
    replan: bool,
) -> ! {
    use deft::analysis::{lint_plan, LintOptions};
    use deft::sched::{run_lifecycle, FallbackReason, LifecycleOptions, ReplanOptions};
    use std::fmt::Write as _;

    // The lint grid reads its cells from the sweep definition, so the
    // static verifier and the batch sweep always cover the same
    // model-zoo × preset × topology space (`ranks_per_node` 1 → flat,
    // n → hier<n>).
    let grid = deft::sweep::SweepGrid::full();
    let mut schemes = Scheme::ALL.to_vec();
    schemes.push(Scheme::DeftNoMultilink);
    let (mut jsonl, mut plans, mut skipped) = (String::new(), 0usize, 0usize);
    let (mut errors, mut warnings) = (0usize, 0usize);
    let (mut fault_jsonl, mut fault_events, mut faulted_cells) = (String::new(), 0usize, 0usize);
    println!("stat workload   preset       topo  scheme             diagnostics");
    for wname in &grid.workloads {
        let workload = workload_by_name(wname).expect("sweep-grid workload");
        for pname in &grid.presets {
            let preset = LinkPreset::parse(pname).expect("sweep-grid preset");
            for &rpn in &grid.ranks_per_node {
                let topo = if rpn > 1 {
                    format!("hier{rpn}")
                } else {
                    "flat".to_string()
                };
                let mut env = preset.env();
                if rpn > 1 {
                    env = env.with_topology(Topology::hierarchical(rpn, LinkId(0), LinkId(1)));
                }
                let spec = fault_scenario.map(|s| fault_spec_for(s, env.workers, fault_seed));
                let opts = LintOptions {
                    fault_envelope: spec.clone(),
                    ..LintOptions::default()
                };
                for &scheme in &schemes {
                    let buckets = match partition_for(
                        &workload, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB,
                    ) {
                        Ok(b) => b,
                        Err(e) => {
                            skipped += 1;
                            println!(
                                "skip {wname:10} {:12} {topo:5} {:18} partition: {e:#}",
                                preset.name(),
                                scheme.name()
                            );
                            continue;
                        }
                    };
                    let schedule = scheduler_for(scheme, true, &env).schedule(&buckets);
                    let report = lint_plan(&schedule, &buckets, &env, &opts);
                    plans += 1;
                    errors += report.error_count();
                    warnings += report.warning_count();
                    for d in &report.diagnostics {
                        writeln!(
                            jsonl,
                            "{{\"workload\":\"{wname}\",\"preset\":\"{}\",\"topology\":\"{topo}\",\"scheme\":\"{}\",{}}}",
                            preset.name(),
                            scheme.name(),
                            d.to_json_fields()
                        )
                        .expect("string write");
                    }
                    println!(
                        "{:4} {wname:10} {:12} {topo:5} {:18} {} error(s), {} warning(s)",
                        if report.is_clean() { "ok" } else { "FAIL" },
                        preset.name(),
                        scheme.name(),
                        report.error_count(),
                        report.warning_count()
                    );
                    if !report.is_clean() {
                        for line in report.render_text().lines() {
                            println!("     {line}");
                        }
                    }
                    if let Some(spec) = &spec {
                        let warmup = schedule.warmup_iters + schedule.cycle.len() + 2;
                        let sopts = SimOptions {
                            iterations: (warmup * 3 + 4).max(12),
                            warmup,
                            record_timeline: false,
                        };
                        let indexed =
                            simulate_faulted(&buckets, &schedule, &env, &sopts, Some(spec));
                        let scan =
                            simulate_scan_faulted(&buckets, &schedule, &env, &sopts, Some(spec));
                        assert!(
                            indexed == scan,
                            "engines diverge under faults: {wname}/{}/{topo}/{}",
                            preset.name(),
                            scheme.name()
                        );
                        faulted_cells += 1;
                        fault_events += indexed.fault_log.len();
                        for e in &indexed.fault_log {
                            writeln!(
                                fault_jsonl,
                                "{{\"workload\":\"{wname}\",\"preset\":\"{}\",\"topology\":\"{topo}\",\"scheme\":\"{}\",\"fault\":{}}}",
                                preset.name(),
                                scheme.name(),
                                e.to_json()
                            )
                            .expect("string write");
                        }
                    }
                }
                // The closed-loop row: a full DeFT lifecycle with
                // measured-drift re-planning, whose accepted schedule
                // must itself lint clean.
                if let (true, Some(spec)) = (replan, &spec) {
                    let opts = LifecycleOptions {
                        faults: Some(spec.clone()),
                        replan: ReplanOptions {
                            enabled: true,
                            ..ReplanOptions::default()
                        },
                        ..LifecycleOptions::default()
                    };
                    match run_lifecycle(&workload, &env, &opts) {
                        Ok(rep) => {
                            plans += 1;
                            errors += rep.lint.error_count();
                            warnings += rep.lint.warning_count();
                            let label = match rep.fallback {
                                FallbackReason::None => "none",
                                FallbackReason::CodecGateRejected { .. } => "codec-gate",
                                FallbackReason::LintRejected { .. } => "lint",
                                FallbackReason::DriftGateRejected { .. } => "drift-gate",
                                FallbackReason::Replanned { .. } => "replanned",
                            };
                            println!(
                                "{:4} {wname:10} {:12} {topo:5} {:18} {} error(s), {} warning(s), fallback {label}",
                                if rep.lint.is_clean() { "ok" } else { "FAIL" },
                                preset.name(),
                                "deft+replan",
                                rep.lint.error_count(),
                                rep.lint.warning_count()
                            );
                            faulted_cells += 1;
                            fault_events += rep.trial.fault_log.len();
                            for e in &rep.trial.fault_log {
                                writeln!(
                                    fault_jsonl,
                                    "{{\"workload\":\"{wname}\",\"preset\":\"{}\",\"topology\":\"{topo}\",\"scheme\":\"deft+replan\",\"fault\":{}}}",
                                    preset.name(),
                                    e.to_json()
                                )
                                .expect("string write");
                            }
                        }
                        Err(e) => {
                            skipped += 1;
                            println!(
                                "skip {wname:10} {:12} {topo:5} {:18} lifecycle: {e:#}",
                                preset.name(),
                                "deft+replan"
                            );
                        }
                    }
                }
            }
        }
    }
    if let Some(path) = lint_json {
        std::fs::write(path, &jsonl)
            .unwrap_or_else(|e| panic!("writing lint report `{path}`: {e}"));
        println!("wrote diagnostics to {path}");
    }
    if let Some(path) = fault_log {
        std::fs::write(path, &fault_jsonl)
            .unwrap_or_else(|e| panic!("writing fault log `{path}`: {e}"));
        println!("wrote fault log to {path}");
    }
    if let Some(name) = fault_scenario {
        println!(
            "fault grid: scenario `{name}` simulated on {faulted_cells} cell(s), \
             {fault_events} fault event(s), engines agree"
        );
    }
    println!(
        "lint grid: {plans} plan(s) linted, {skipped} skipped, {errors} error(s), {warnings} warning(s)"
    );
    std::process::exit(i32::from(errors > 0));
}

fn main() {
    let Args {
        workload: name,
        preset,
        ranks_per_node,
        codecs,
        contention,
        faults,
        fault_seed,
        fault_log,
    } = parse_args();
    let workload = workload_by_name(&name).unwrap_or_else(|e| panic!("{e:#}"));
    let mut env = preset.env().with_contention_model(contention);
    if ranks_per_node > 1 {
        env = env.with_topology(Topology::hierarchical(ranks_per_node, LinkId(0), LinkId(1)));
    }
    for (link_name, codec) in &codecs {
        let id = env.link(link_name).unwrap_or_else(|| {
            panic!(
                "--codec: unknown link `{link_name}` (registry: {})",
                env.link_names().join(", ")
            )
        });
        env = env.with_codec(id, *codec);
    }
    if env.has_lossy_codec() {
        println!(
            "codecs: {}\n",
            env.links
                .iter()
                .map(|l| format!("{}={}", l.name, l.codec.name()))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }

    println!("=== Table III: scheme feature matrix ===\n{}", feature_matrix());

    println!("=== Profiler round-trip (paper Fig. 8) ===");
    let topts = TraceOptions::uniform(&workload, 6);
    let (events, truth) = generate_trace(&workload, &topts);
    println!(
        "generated {} raw operator events across 4 threads",
        events.len()
    );
    let rec = reconstruct(&events);
    println!("bucket |   fwd(us) true/rec |   bwd(us) true/rec |  comm(us) true/rec");
    for (r, t) in rec.iter().zip(truth.buckets.iter()) {
        println!(
            "  #{:<3} | {:>8} / {:<8} | {:>8} / {:<8} | {:>8} / {:<8}",
            r.id + 1,
            t.0.as_us(),
            r.fwd.as_us(),
            t.1.as_us(),
            r.bwd.as_us(),
            t.2.as_us(),
            r.comm.as_us()
        );
    }

    // Feed the reconstructed profile straight into the schedulers.
    let buckets: Vec<BucketProfile> = rec
        .iter()
        .zip(workload.layers.chunks(workload.num_layers() / 6 + 1))
        .map(|(r, chunk)| BucketProfile {
            id: r.id,
            params: chunk.iter().map(|l| l.params).sum(),
            fwd: r.fwd,
            bwd: r.bwd,
            comm: r.comm,
        })
        .collect();
    let _ = buckets; // (the pipeline below re-partitions per scheme)

    println!(
        "\n=== Scheduling orders (paper Figs. 11-13) for {} on {} ({}; contention: {}) ===",
        workload.name,
        preset.name(),
        env.link_names().join("+"),
        env.contention.name()
    );
    let fault_spec = faults
        .as_deref()
        .map(|s| fault_spec_for(s, env.workers, fault_seed));
    if let Some(name) = &faults {
        println!("\nfaults: scenario `{name}` injected into every simulation below");
    }
    let mut fault_jsonl = String::new();
    let mut schemes = Scheme::ALL.to_vec();
    schemes.push(Scheme::DeftNoMultilink);
    for scheme in schemes {
        let r = run_pipeline(&workload, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 40)
            .expect("pipeline");
        println!(
            "\n--- {} ({} buckets, iter {} | bubbles {:.1}%) ---",
            scheme.name(),
            r.buckets.len(),
            r.sim.steady_iter_time,
            r.sim.bubble_ratio() * 100.0
        );
        println!("{}", gantt_steady(&r.sim, r.schedule.cycle.len(), 110));
        println!("{}", link_table(&r.sim));
        if let Some(spec) = &fault_spec {
            let warmup = r.schedule.warmup_iters + r.schedule.cycle.len() + 2;
            let sopts = SimOptions {
                iterations: (warmup * 3 + 4).max(40),
                warmup,
                record_timeline: false,
            };
            let faulted = simulate_faulted(&r.buckets, &r.schedule, &env, &sopts, Some(spec));
            let scan = simulate_scan_faulted(&r.buckets, &r.schedule, &env, &sopts, Some(spec));
            assert!(
                faulted == scan,
                "engines diverge under faults for {}",
                scheme.name()
            );
            println!(
                "    faulted: iter {} ({:.2}x healthy), {} fault event(s)",
                faulted.steady_iter_time,
                faulted.steady_iter_time.ratio(r.sim.steady_iter_time),
                faulted.fault_log.len()
            );
            for e in &faulted.fault_log {
                use std::fmt::Write as _;
                writeln!(
                    fault_jsonl,
                    "{{\"workload\":\"{}\",\"scheme\":\"{}\",\"fault\":{}}}",
                    workload.name,
                    scheme.name(),
                    e.to_json()
                )
                .expect("string write");
            }
        }
    }
    if let Some(path) = &fault_log {
        std::fs::write(path, &fault_jsonl)
            .unwrap_or_else(|e| panic!("writing fault log `{path}`: {e}"));
        println!("\nwrote fault log to {path}");
    }
}
