//! Preserver walkthrough: quantify the convergence impact of DeFT's
//! variable-batch-size sequences (paper §IV.C, Table V) and show the
//! feedback loop adjusting knapsack capacity.
//!
//! Run: `cargo run --release --example preserver_demo`

use deft::bench::{PAPER_DDP_MB, PAPER_PARTITION};
use deft::bench::{run_pipeline, workload_by_name};
use deft::config::Scheme;
use deft::links::{ClusterEnv, Codec, LinkId};
use deft::metrics::Table;
use deft::preserver::{acceptable, quantify, quantify_with_error, table5_setting, EPSILON};
use deft::sched::{run_lifecycle, LifecycleOptions};

fn main() {
    let (walk, base_batch) = table5_setting();
    println!(
        "Gaussian-walk setting (Table V): s_A = {}, eta = {}, B = {base_batch}\n",
        walk.s_t, walk.eta
    );

    println!("=== expected-state evolution for candidate k-sequences ===");
    let mut t = Table::new(&["k sequence", "E_OB(final)", "E_OD(final)", "ratio", "acceptable(eps=0.01)"]);
    for ks in [
        vec![1u64, 1, 1, 1],
        vec![2, 1, 1],
        vec![2, 2],
        vec![4],
        vec![8],
        vec![16],
        vec![64],
    ] {
        let rep = quantify(&walk, base_batch, &ks);
        t.row(&[
            format!("{ks:?}"),
            format!("{:.4}", rep.baseline.last().unwrap()),
            format!("{:.4}", rep.deft.last().unwrap()),
            format!("{:.4}", rep.ratio),
            acceptable(&rep, EPSILON).to_string(),
        ]);
    }
    println!("{}", t.render());

    println!("=== feedback loop in action (VGG-19) ===");
    let w = workload_by_name("vgg19").expect("workload");
    let env = ClusterEnv::paper_testbed();
    for (label, preserver) in [("preserver OFF", false), ("preserver ON", true)] {
        let scheme = Scheme::Deft;
        let r = if preserver {
            run_pipeline(&w, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 40).expect("pipeline")
        } else {
            // The pipeline always builds DeFT with the preserver on; build
            // the raw scheduler by hand for the OFF row.
            use deft::partition::{partition, Strategy};
            use deft::sched::{Deft, DeftOptions, Scheduler};
            use deft::sim::{simulate, SimOptions};
            let buckets = partition(
                &w,
                Strategy::DeftConstrained {
                    partition_size: PAPER_PARTITION,
                },
                &env,
            )
            .expect("partition");
            let schedule = Deft::new(DeftOptions {
                preserver: false,
                ..DeftOptions::default()
            })
            .schedule(&buckets);
            let sim = simulate(
                &buckets,
                &schedule,
                &env,
                &SimOptions {
                    iterations: 40,
                    warmup: schedule.cycle.len().max(4),
                    record_timeline: false,
                },
            );
            deft::bench::PipelineResult {
                buckets,
                schedule,
                sim,
            }
        };
        let rep = quantify(&walk, base_batch, &r.schedule.batch_multipliers);
        println!(
            "{label:>14}: update freq {:.2}, k = {:?}, walk ratio {:.4}, iter {}",
            r.schedule.update_frequency(),
            r.schedule.batch_multipliers,
            rep.ratio,
            r.sim.steady_iter_time
        );
    }
    println!("\nThe feedback mechanism raises knapsack capacity until the walk\nratio re-enters [1-eps, 1+eps], trading a little overlap for accuracy.");

    // === Codec error gate: lossy links must clear the same walk. ===
    println!("\n=== codec error gate (k = [2, 1, 1]) ===");
    let mut ct = Table::new(&["codec", "gradient error", "walk ratio", "acceptable(eps=0.01)"]);
    for codec in [
        Codec::Raw,
        Codec::Fp16,
        Codec::RankK { k: 16 },
        Codec::RankK { k: 4 },
        Codec::RankK { k: 1 },
    ] {
        let rep = quantify_with_error(&walk, base_batch, &[2, 1, 1], codec.error());
        ct.row(&[
            codec.name(),
            format!("{:.3}", codec.error()),
            format!("{:.4}", rep.ratio),
            acceptable(&rep, EPSILON).to_string(),
        ]);
    }
    println!("{}", ct.render());

    // A rejected codec forces the lifecycle back onto raw links.
    let lossy = ClusterEnv::paper_testbed().with_codec(LinkId(1), Codec::RankK { k: 1 });
    let rep =
        run_lifecycle(&w, &lossy, &LifecycleOptions::default()).expect("lifecycle lint gate");
    println!(
        "lifecycle on rank1-gloo: codec_fallback = {} (attempts: {:?})",
        rep.codec_fallback,
        rep.attempts
            .iter()
            .map(|(s, r)| format!("scale {s:.2} ratio {r:.4}"))
            .collect::<Vec<_>>()
    );
}
