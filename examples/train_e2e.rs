//! END-TO-END VALIDATION (the repo's required driver): train the small
//! transformer with REAL gradients through all three layers —
//!
//!   L1 Pallas kernels (attention fwd, bucket reduce, fused SGD) →
//!   L2 JAX train_step/apply_update, AOT-lowered to HLO text →
//!   L3 Rust coordinator executing via PJRT, with DeFT's delayed-update
//!      queue algebra applied to the actual gradient buffers,
//!
//! comparing DeFT against the PyTorch-DDP baseline semantics: both runs
//! see identical data streams; we verify the loss curves track (the
//! paper's "no loss of accuracy" claim) while the co-simulated wall
//! clock shows DeFT's speedup.
//!
//! Needs `make artifacts`. Run:
//!   cargo run --release --example train_e2e -- [iterations] [workers]
//!
//! Results are recorded in EXPERIMENTS.md.

use deft::config::Scheme;
use deft::links::ClusterEnv;
use deft::metrics::Table;
use deft::train::{TrainOptions, Trainer};
use deft::util::error::Result;

fn main() -> Result<()> {
    let iterations: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let workers: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);

    if !std::path::Path::new("artifacts/manifest.toml").exists() {
        deft::bail!("artifacts/manifest.toml missing — run `make artifacts` first");
    }

    // One shared measured profile set keeps the scheme comparison fair
    // (profiling twice on a loaded machine adds noise).
    let mut shared_profiles = None;
    let mut reports = Vec::new();
    for scheme in [Scheme::PytorchDdp, Scheme::Deft] {
        let env = ClusterEnv::paper_testbed().with_workers(workers);
        let opts = TrainOptions {
            manifest: "artifacts/manifest.toml".into(),
            scheme,
            workers,
            iterations,
            lr: 0.25,
            momentum: 0.9,
            seed: 23,
            log_every: (iterations / 20).max(1),
            env: env.clone(),
        };
        println!("=== training with {} semantics ===", scheme.name());
        let mut trainer = Trainer::new(opts)?;
        if shared_profiles.is_none() {
            shared_profiles = Some(trainer.profile_buckets(3)?);
        }
        let profiles = shared_profiles.clone().unwrap();
        println!(
            "bucket profiles (CR-targeted 1.5): {:?}",
            profiles
                .iter()
                .map(|b| (b.id, b.params, b.comm.as_ms_f64()))
                .collect::<Vec<_>>()
        );
        let scheduler = deft::bench::scheduler_for(scheme, true, &env);
        let schedule = scheduler.schedule(&profiles);
        println!(
            "schedule: cycle {} iters, {} updates, k = {:?}",
            schedule.cycle.len(),
            schedule.updates_per_cycle,
            schedule.batch_multipliers
        );
        let report = trainer.run(&schedule, &profiles)?;
        println!(
            "updates = {}   measured step = {}   simulated iter = {}",
            report.updates, report.measured_step, report.sim_iter_time
        );
        for (it, loss) in &report.losses {
            println!("  iter {it:>5}   loss {loss:.4}");
        }
        reports.push(report);
    }

    let ddp = &reports[0];
    let deft = &reports[1];
    println!("\n=== summary ===");
    let mut t = Table::new(&["scheme", "final loss", "updates", "sim iter time", "speedup"]);
    for r in &reports {
        t.row(&[
            r.scheme.clone(),
            format!("{:.4}", r.final_loss),
            r.updates.to_string(),
            format!("{}", r.sim_iter_time),
            format!("{:.2}x", ddp.sim_iter_time.ratio(r.sim_iter_time)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "uniform-distribution loss = {:.3}; both runs must land well below it",
        ddp.uniform_loss
    );
    let gap = (deft.final_loss - ddp.final_loss).abs();
    println!(
        "|DeFT - DDP| final-loss gap = {gap:.4} ({}% of DDP)",
        (100.0 * gap / ddp.final_loss) as i64
    );
    if ddp.final_loss >= ddp.uniform_loss * 0.85 {
        deft::bail!("DDP run failed to learn");
    }
    if deft.final_loss >= deft.uniform_loss * 0.9 {
        deft::bail!("DeFT run failed to learn");
    }
    println!("OK: end-to-end three-layer training validated.");
    Ok(())
}
